"""Open-loop SLO characterization of the async serving layer.

Closed-loop clients (benchmarks/serving_load.py) self-throttle: a slow
server slows its own offered load, so saturation sweeps can never show
what overload does to tail latency and deadline misses.  This benchmark
drives the paper's missing half — a seeded **Poisson arrival process**
whose rate does not care how the server is doing — through
:class:`~repro.serve.graph_engine.AsyncGraphServer` on a
:class:`~repro.serve.scheduler.FakeClock`, at offered loads {0.5, 1.0,
2.0}x the measured closed-loop capacity.

Discrete-event simulation on the fake clock, with real compute:

* arrivals are seeded exponential gaps at ``mult x capacity``; the
  event loop advances the clock to ``min(next arrival, next window
  due)`` and either admits the query (absolute deadline = its arrival
  instant + one fixed latency budget) or polls the scheduler;
* service consumes **simulated time equal to its measured wall time**
  (the tenant's flush is timed with ``perf_counter`` and the fake clock
  advances by exactly that much before tickets resolve), so backlog —
  and therefore deadline misses — accumulate under overload exactly as
  they would on a wall clock, while every scheduling decision stays
  single-threaded and reproducible;
* a request's latency is ``resolved_at - arrival`` on the simulated
  timeline (queueing + batch formation + service).

Asserted in-process, per load: the tenant's ``stats()["slo"]`` deadline
misses equal the per-ticket slack oracle (misses counted exactly once),
and every conservation invariant holds (``admitted == dispatched +
pending + abandoned``, ``goodput + deadline_misses + no_deadline ==
resolved``).  Across loads: miss rate is monotone non-decreasing with a
strict 0.5x < 2.0x gap, and the answer checksums are **identical at
every load** — overload degrades latency, never answers.  The same
checksum gates in CI via benchmarks/baseline.json (the answers are
timing-independent; every latency/miss-rate number is artifact data).

The ``stitched`` case replays a two-window workload traced and
untraced: payloads must be bit-identical, and every span the traced
drain emits — ``serve/submit``/``serve/window``/``serve/flush``, the
bucket pipeline's ``pipeline/*`` spans, enqueue waits — must carry the
``window_id`` stitching attrs (obs.trace.Tracer.context), re-validated
from the exported Perfetto JSON (``$SLO_TRACE_OUT``, default
``slo-trace.json``).

A machine-readable summary (offered-load curve + per-tenant SLO table)
is written to ``$SLO_STATS_OUT`` (default ``slo-stats.json``) for
tools/slo_report.py to render into ``$GITHUB_STEP_SUMMARY``.
"""
from benchmarks import common  # noqa: F401  (must be first: device count)

import hashlib
import json
import os
import time

import numpy as np

from benchmarks.common import emit
from repro.graphs import generate
from repro.obs import trace
from repro.obs.metrics import percentile_exact
from repro.serve.graph_engine import AsyncGraphServer, GraphQueryServer
from repro.serve.scheduler import FakeClock

ALGS = ("bfs", "sssp", "ppr")
BATCH = 8
LOADS = (0.5, 1.0, 2.0)
#: checksummed payload field per algorithm (integer-exact answers only:
#: bfs levels and sssp distances over content-keyed integer weights)
CSUM_FIELD = {"bfs": "levels", "sssp": "dist"}


def _csum(arrays) -> str:
    h = hashlib.sha1()
    for a in arrays:
        a = np.asarray(a, np.float64)
        h.update(np.where(np.isfinite(a), a, -1.0).astype(np.int64).tobytes())
    return h.hexdigest()[:12]


def _workload(graph, n: int, seed: int = 11):
    rng = np.random.default_rng(seed)
    return [(ALGS[int(a)], int(s))
            for a, s in zip(rng.integers(0, len(ALGS), n),
                            rng.integers(0, graph.n, n))]


def _make_server(graph, max_wait: float):
    """An async server on a fresh FakeClock whose tenant flush consumes
    simulated time equal to its measured wall time — the discrete-event
    bridge between real compute and the deterministic timeline.  Caching
    is disabled (capacity 0) so every query costs real service time and
    the capacity measurement transfers to the open-loop runs."""
    clock = FakeClock()
    srv = AsyncGraphServer(clock=clock, max_pending=1 << 16,
                           cache_capacity=0)
    srv.add_tenant("t", graph, batch_size=BATCH, max_wait=max_wait)
    server = srv.tenant("t")
    orig_flush = server.flush

    def timed_flush():
        t0 = time.perf_counter()
        out = orig_flush()
        clock.advance(time.perf_counter() - t0)
        return out

    server.flush = timed_flush
    # compile warmup (deadline-less: lands in slo["no_deadline"], never
    # skews the miss rate) — one query per algorithm primes every runner
    for a in ALGS:
        srv.submit("t", a, 0)
    srv.drain("t")
    return srv, clock


def _capacity(graph, queries) -> float:
    """Saturation capacity: the deep-backlog coalesced service rate.

    Under open-loop overload the scheduler coalesces the backlog into
    large windows, and the engine buckets a window per algorithm — so a
    mixed-algorithm window of BATCH leaves its padded buckets ~1/3 full
    while a backlogged window runs them full.  Stability is therefore
    governed by the *coalesced* throughput, not the small-window one:
    measure it by draining the whole workload as a single window (two
    passes, best wall — the first warms residual compilation) on the
    same server machinery the open-loop runs use."""
    srv, _ = _make_server(graph, max_wait=1e9)
    best = 0.0
    for _ in range(2):
        t0 = time.perf_counter()
        for alg, src in queries:
            srv.submit("t", alg, src)
        srv.drain("t")
        best = max(best, len(queries) / (time.perf_counter() - t0))
    return best


def _openloop(graph, queries, mult: float, capacity: float,
              max_wait: float, budget: float, seed: int):
    """One offered-load point: Poisson arrivals at ``mult x capacity``
    through the windowed scheduler, every request's absolute deadline
    pinned to its arrival + ``budget``."""
    srv, clock = _make_server(graph, max_wait=max_wait)
    rate = mult * capacity
    rng = np.random.default_rng(seed)
    # the warmup drain consumed simulated time (compilation is service
    # too) — the arrival process starts from the post-warmup clock
    arrivals = clock.now() + np.cumsum(
        rng.exponential(1.0 / rate, len(queries)))
    tickets = []
    i, n = 0, len(queries)
    sched = srv.scheduler
    while i < n or sched.pending() > 0:
        due = sched.next_wakeup()
        if i < n and (due is None or arrivals[i] <= due):
            now = clock.now()
            if arrivals[i] > now:
                clock.advance(arrivals[i] - now)
            alg, src = queries[i]
            # relative deadline vs *now*: under backlog the arrival is in
            # the simulated past, so the absolute deadline stays pinned
            # at arrival + budget (possibly already expired)
            tickets.append(srv.submit(
                "t", alg, src,
                deadline=float(arrivals[i] + budget - clock.now())))
            i += 1
        else:
            now = clock.now()
            if due > now:
                clock.advance(due - now)
            srv.poll()       # flush advances the clock by its wall time

    lat = np.array([tk.resolved_at - a for tk, a in zip(tickets, arrivals)])
    st = srv.stats("t")
    slo = st["slo"]
    # -- accounting invariants, asserted on the real run ------------------
    assert slo["pending"] == 0 and slo["abandoned"] == 0
    assert slo["admitted"] == slo["dispatched"] + slo["pending"] \
        + slo["abandoned"]
    assert slo["goodput"] + slo["deadline_misses"] + slo["no_deadline"] \
        == slo["resolved"] == slo["dispatched"]
    assert slo["no_deadline"] == len(ALGS)          # exactly the warmups
    assert slo["slack_s"]["count"] == slo["goodput"] \
        + slo["deadline_misses"] == n
    # misses counted exactly once, equal to the per-ticket slack oracle
    oracle = sum(1 for tk in tickets if tk.slack() < 0)
    assert slo["deadline_misses"] == oracle, (slo["deadline_misses"], oracle)
    assert slo["lateness_s"]["count"] == oracle

    miss_rate = slo["deadline_misses"] / n
    payloads = [tk.result for tk in tickets]
    csum = _csum([payloads[j][CSUM_FIELD[alg]]
                  for j, (alg, _) in enumerate(queries)
                  if alg in CSUM_FIELD])
    return {"offered_x": mult, "offered_qps": rate, "n": n,
            "p50_ms": percentile_exact(list(lat), 0.50) * 1e3,
            "p99_ms": percentile_exact(list(lat), 0.99) * 1e3,
            "miss_rate": miss_rate, "goodput_rate": slo["goodput"] / n,
            "misses": slo["deadline_misses"],
            "abandoned": slo["abandoned"], "checksum": csum,
            "slo": slo, "tickets": tickets, "payloads": payloads}


# ------------------------------------------------------------- stitching
def _replay_two_windows(graph, queries):
    """Submit ``queries`` as two size-BATCH windows and drain each —
    returns (payloads, window_ids)."""
    srv, _ = _make_server(graph, max_wait=1e9)
    payloads, wids = [], []
    for lo in range(0, len(queries), BATCH):
        tks = [srv.submit("t", alg, src)
               for alg, src in queries[lo:lo + BATCH]]
        srv.drain("t")
        payloads.extend(tk.result for tk in tks)
        wids.extend(tk.window_id for tk in tks)
    return payloads, wids


def _stitched_trace(graph, queries):
    """Traced == untraced bit-identity with stitched spans enabled, and
    every span of the traced drain carries the window_id attrs — in the
    live tracer and re-validated from the Perfetto export."""
    ref, _ = _replay_two_windows(graph, queries)
    tr = trace.Tracer()
    with trace.tracing(tr):
        got, wids = _replay_two_windows(graph, queries)

    for r, g in zip(ref, got):          # bit-identity, every field
        assert sorted(r) == sorted(g)
        for k in r:
            np.testing.assert_array_equal(np.asarray(r[k]),
                                          np.asarray(g[k]))

    # the traced replay also traces the warmup window, so span counts
    # are filtered to the measured windows' ids
    windows = sorted(set(wids))
    assert len(windows) == (len(queries) + BATCH - 1) // BATCH
    submits = [s for s in tr.filter("serve/submit")
               if s.attrs["window_id"] in windows]
    assert len(submits) == len(queries)
    assert all(s.attrs["request_id"] for s in submits)
    assert len([s for s in tr.filter("serve/window")
                if s.attrs["window_id"] in windows]) == len(queries)
    flushes = [s for s in tr.filter("serve/flush")
               if s.attrs.get("window_id") in windows]
    assert len(flushes) == len(windows)
    # every span any drain emitted — flush, enqueue waits, the bucket
    # pipeline's issue/materialize, bucket compute/payload — inherited
    # the ambient window_id/request_ids, and every measured window shows
    # up stitched
    stitched = [s for s in tr.spans
                if s.name.startswith(("pipeline/", "serve/bucket",
                                      "serve/payload", "serve/enqueue",
                                      "serve/flush"))]
    assert stitched, "drain emitted no downstream spans"
    for s in stitched:
        assert "window_id" in s.attrs, (s.name, s.attrs)
        assert "request_ids" in s.attrs, s.name
    assert {s.attrs["window_id"] for s in stitched} >= set(windows)

    out = os.environ.get("SLO_TRACE_OUT", "slo-trace.json")
    n_events = tr.export_chrome_trace(out)
    with open(out) as fh:
        doc = json.load(fh)
    assert len(doc["traceEvents"]) == n_events
    for ev in doc["traceEvents"]:
        if ev["name"].startswith(("serve/", "pipeline/")):
            assert "window_id" in ev["args"], ev["name"]
    csum = _csum([got[j][CSUM_FIELD[alg]]
                  for j, (alg, _) in enumerate(queries)
                  if alg in CSUM_FIELD])
    emit("slo_openloop", "stitched", n_spans=n_events,
         n_windows=len(windows), n_queries=len(queries), checksum=csum)


def run(quick: bool = False):
    graph = generate("face", scale=0.12, seed=5)
    n = 120 if quick else 240
    queries = _workload(graph, n)
    capacity = _capacity(graph, queries[: max(BATCH * 6, n // 2)])
    emit("slo_openloop", "capacity", queries_per_s=capacity)
    # one full batch gathers in 16 query-service-times at 0.5x offered
    # load; the budget leaves ~2x headroom over the 0.5x steady-state
    # latency (window fill + partially-filled-bucket service), while a
    # 2x run's backlog grows past it within the workload — capacity is
    # the saturation rate, so 2x is structurally unsustainable
    t_q = 1.0 / capacity
    max_wait = 16 * t_q
    budget = 64 * t_q

    by_mult = {}
    for mult in LOADS:
        m = _openloop(graph, queries, mult, capacity, max_wait, budget,
                      seed=int(mult * 100))
        by_mult[mult] = m
        emit("slo_openloop", f"load{mult:g}x",
             **{k: v for k, v in m.items()
                if k not in ("slo", "tickets", "payloads")})

    # overload degrades deadlines monotonically — and never answers
    mr = {m: by_mult[m]["miss_rate"] for m in LOADS}
    assert mr[0.5] <= mr[1.0] + 0.1, mr
    assert mr[1.0] <= mr[2.0] + 0.1, mr
    assert mr[2.0] >= mr[0.5] + 0.15, mr
    csums = {by_mult[m]["checksum"] for m in LOADS}
    assert len(csums) == 1, csums

    # async == sync oracle on the same workload, element-exact
    ssrv = GraphQueryServer(graph, batch_size=BATCH)
    reqs = [ssrv.submit(alg, src) for alg, src in queries]
    ssrv.flush()
    field = {"bfs": "levels", "sssp": "dist", "ppr": "rank"}
    for tk, rq, (alg, _) in zip(by_mult[1.0]["tickets"], reqs, queries):
        np.testing.assert_array_equal(
            np.asarray(tk.result[field[alg]]),
            np.asarray(rq.result[field[alg]]),
            err_msg=f"async != sync for {alg}")
    emit("slo_openloop", "oracle", n=n, checksum=csums.pop())

    _stitched_trace(graph, queries[: 2 * BATCH])

    stats_out = os.environ.get("SLO_STATS_OUT", "slo-stats.json")
    doc = {
        "bench": "slo_openloop",
        "capacity_qps": capacity,
        "budget_ms": budget * 1e3,
        "curve": [{k: by_mult[m][k]
                   for k in ("offered_x", "offered_qps", "n", "p50_ms",
                             "p99_ms", "miss_rate", "goodput_rate",
                             "misses", "abandoned")}
                  for m in LOADS],
        "tenants": [{"tenant": "t", "case": f"load{m:g}x",
                     **{k: v for k, v in by_mult[m]["slo"].items()
                        if not isinstance(v, dict)},
                     "worst_slack_ms":
                         by_mult[m]["slo"]["slack_s"].get("min", 0.0) * 1e3}
                    for m in LOADS],
    }
    with open(stats_out, "w") as fh:
        json.dump(doc, fh, indent=2, default=float)
    print(f"slo_openloop: wrote SLO summary to {stats_out}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)

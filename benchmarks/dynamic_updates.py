"""Streaming-update benchmark: delta apply throughput + incremental vs
cold recompute per Table-2 family (graphs/dynamic.py over core/delta.py).

Per family × delta kind (``grow`` = insert-only batch, ``churn`` = mixed
insert+delete batch), rows report for BFS / SSSP the **element traffic**
(frontier elements the kernel consumed, the paper's Load-phase currency —
incremental includes the shared reachability repair pass) and wall time;
for CC / PageRank the **iteration counts** (dense whole-vertex rounds, so
iterations ∝ traffic). Wall numbers are artifact data only (2-core CI
runners); every assertion is on deterministic quantities:

* incremental results are **element-exact** vs cold recompute on every
  delta batch, for BFS, SSSP and CC (the ISSUE-5 acceptance bar);
* on ``grow`` batches incremental element traffic < cold on every family
  (road / uniform / rmat), and incremental CC iterations ≤ cold;
* warm-restart PageRank converges in fewer iterations than cold on the
  regular families (road / uniform; rmat hub perturbations can favour the
  uniform start, so its row is reported, not asserted);
* the query server's ``mutate()`` retains ≥ 1 cache entry across the
  delta while invalidating the affected ones (proved via ``stats()``).

Cold-result checksums are integer-exact (levels / labels are ints; SSSP
distances are sums of content-keyed integer weights, exact in f32) and
gate in CI via tools/compare_bench.py against benchmarks/baseline.json.
"""
from benchmarks import common  # noqa: F401  (must be first: device count)

import hashlib
import time

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.delta import EdgeDelta, apply_edge_delta, canonicalize
from repro.core.semiring import BOOL_OR_AND, MIN_PLUS, MIN_TIMES, PLUS_TIMES
from repro.graphs import datasets
from repro.graphs.analytics import connected_components
from repro.graphs.dynamic import (
    DynamicGraph, bfs_incremental, cc_incremental, pagerank_warm,
    plan_repair, sssp_incremental, traffic_of,
)
from repro.graphs.engine import build_engine
from repro.graphs.multi import bfs_multi, sssp_multi
from repro.graphs.ppr import pagerank
from repro.serve.graph_engine import GraphQueryServer

MAX_ITERS = 512        # covers every family's diameter at both scales
PR_ITERS = 200


def _graphs(quick: bool):
    s = 1 if quick else 3
    return [
        ("road", datasets.road_graph(1600 * s, 2.6, seed=0)),
        ("uniform", datasets.uniform_graph(1500 * s, 6000 * s, seed=0)),
        ("rmat", datasets.rmat_graph(2048 * s, 16000 * s, skew=0.6, seed=0)),
    ]


def _local_inserts(g, k: int, rng):
    """Triangle-closing insert candidates: for k random edges (u, v), a
    random neighbour w of v gives a new (u, w) edge. Streamed graph
    updates are overwhelmingly local (new links attach near existing
    ones); locality is also what keeps the answer delta — and with it the
    incremental ripple — small. Uniformly random endpoints would instead
    act as small-world shortcuts on the road lattice and legitimately
    shrink most shortest paths, making cold recompute the honest
    choice."""
    order = np.argsort(g.rows, kind="stable")
    sorted_cols = g.cols[order]
    ptr = np.searchsorted(g.rows[order], np.arange(g.n + 1))
    e = rng.choice(g.nnz, k, replace=True)
    u, v = g.rows[e], g.cols[e]
    deg = ptr[v + 1] - ptr[v]           # ≥ 1: v has out-edges (symmetric)
    off = (rng.random(k) * deg).astype(np.int64)
    w = sorted_cols[ptr[v] + off]
    return u, w                          # self loops/duplicates: no-ops


def _deltas(g):
    """One insert-only and one mixed batch per family, sized ~1% of nnz."""
    rng = np.random.default_rng(11)
    k = max(8, g.nnz // 100)
    gu, gw = _local_inserts(g, k, rng)
    grow = EdgeDelta(insert_rows=gu, insert_cols=gw)
    cu, cw = _local_inserts(g, k, rng)
    drop = rng.choice(g.nnz, max(4, k // 2), replace=False)
    churn = EdgeDelta(cu, cw, g.rows[drop], g.cols[drop])
    return [("grow", grow), ("churn", churn)]


def _csum(arr: np.ndarray) -> str:
    a = np.asarray(arr, np.float64)
    ints = np.where(np.isfinite(a), a, -1.0).astype(np.int64)
    return hashlib.sha1(ints.tobytes()).hexdigest()[:12]


def _apply_throughput(fam: str, g, delta: EdgeDelta, reps: int):
    """Delta apply wall time (pure host set algebra) — min over reps."""
    best = np.inf
    for _ in range(reps):
        t0 = time.perf_counter()
        apply_edge_delta(g.rows, g.cols, g.n, delta)
        best = min(best, time.perf_counter() - t0)
    d = canonicalize(delta, g.n)
    edges = d.n_inserts + d.n_deletes
    emit("dynamic_updates", f"{fam}/apply", wall_ms=best * 1e3,
         edges=edges, edges_per_s=edges / best)


def run(quick: bool = False):
    reps = 3 if quick else 5
    for fam, g0 in _graphs(quick):
        rng = np.random.default_rng(5)
        sources = [int(s) for s in rng.integers(0, g0.n, 4)]
        # previous-epoch answers (the state incremental recompute resumes)
        e0_bool = build_engine(g0, BOOL_OR_AND)
        e0_w = build_engine(g0, MIN_PLUS, weighted=True, seed=5,
                            content_keyed=True)
        e0_cc = build_engine(g0, MIN_TIMES)
        e0_pr = build_engine(g0, PLUS_TIMES, normalize=True)
        old_levels = np.asarray(bfs_multi(e0_bool, sources,
                                          max_iters=MAX_ITERS).levels)
        old_dist = np.asarray(sssp_multi(e0_w, sources,
                                         max_iters=MAX_ITERS).dist)
        old_labels = np.asarray(connected_components(e0_cc).labels)
        old_rank = np.asarray(pagerank(e0_pr, max_iters=PR_ITERS).rank)

        for kind, delta in _deltas(g0):
            if kind == "grow":
                _apply_throughput(fam, g0, delta, reps)
            dg = DynamicGraph(g0)
            g1 = dg.apply(delta)
            d = canonicalize(delta, g0.n)
            e1_unit = build_engine(g1, MIN_PLUS, weighted=False)
            e1_bool = build_engine(g1, BOOL_OR_AND)
            e1_w = build_engine(g1, MIN_PLUS, weighted=True, seed=5,
                                content_keyed=True)
            e1_cc = build_engine(g1, MIN_TIMES)
            e1_pr = build_engine(g1, PLUS_TIMES, normalize=True)
            repair = plan_repair(e1_unit, d)

            # BFS — exactness on every batch, traffic win on grow
            cold = bfs_multi(e1_bool, sources, max_iters=MAX_ITERS)
            inc = bfs_incremental(e1_unit, sources, old_levels, d,
                                  repair=repair, max_iters=MAX_ITERS)
            assert int(np.max(np.asarray(cold.iterations))) < MAX_ITERS
            np.testing.assert_array_equal(inc.values, np.asarray(cold.levels),
                                          err_msg=f"{fam}/{kind}/bfs")
            t_cold = timeit(lambda: bfs_multi(e1_bool, sources,
                                              max_iters=MAX_ITERS),
                            iters=reps, warmup=1)
            t_inc = timeit(lambda: bfs_incremental(
                e1_unit, sources, old_levels, d, repair=repair,
                max_iters=MAX_ITERS), iters=reps, warmup=1)
            traffic_cold = traffic_of(cold)
            traffic_inc = inc.traffic + repair.traffic
            if kind == "grow":
                assert traffic_inc < traffic_cold, (
                    f"{fam}/bfs incremental traffic {traffic_inc} !< "
                    f"cold {traffic_cold}")
            emit("dynamic_updates", f"{fam}/{kind}/bfs",
                 traffic_cold=traffic_cold, traffic_inc=traffic_inc,
                 wall_cold_ms=t_cold * 1e3, wall_inc_ms=t_inc * 1e3,
                 checksum=_csum(np.asarray(cold.levels)))

            # SSSP — same bar over content-keyed integer weights
            cold_w = sssp_multi(e1_w, sources, max_iters=MAX_ITERS)
            inc_w = sssp_incremental(e1_w, sources, old_dist, d,
                                     repair=repair, max_iters=MAX_ITERS)
            np.testing.assert_array_equal(
                inc_w.values, np.asarray(cold_w.dist),
                err_msg=f"{fam}/{kind}/sssp")
            t_cold = timeit(lambda: sssp_multi(e1_w, sources,
                                               max_iters=MAX_ITERS),
                            iters=reps, warmup=1)
            t_inc = timeit(lambda: sssp_incremental(
                e1_w, sources, old_dist, d, repair=repair,
                max_iters=MAX_ITERS), iters=reps, warmup=1)
            traffic_cold = traffic_of(cold_w)
            traffic_inc = inc_w.traffic + repair.traffic
            if kind == "grow":
                assert traffic_inc < traffic_cold, (
                    f"{fam}/sssp incremental traffic {traffic_inc} !< "
                    f"cold {traffic_cold}")
            emit("dynamic_updates", f"{fam}/{kind}/sssp",
                 traffic_cold=traffic_cold, traffic_inc=traffic_inc,
                 wall_cold_ms=t_cold * 1e3, wall_inc_ms=t_inc * 1e3,
                 checksum=_csum(np.asarray(cold_w.dist)))

            # CC — label repair: exact, never more rounds than cold
            cold_cc = connected_components(e1_cc)
            inc_cc = cc_incremental(e1_cc, old_labels, d)
            np.testing.assert_array_equal(
                np.asarray(inc_cc.labels), np.asarray(cold_cc.labels),
                err_msg=f"{fam}/{kind}/cc")
            if kind == "grow":
                assert int(inc_cc.iterations) <= int(cold_cc.iterations)
            emit("dynamic_updates", f"{fam}/{kind}/cc",
                 iters_cold=int(cold_cc.iterations),
                 iters_inc=int(inc_cc.iterations),
                 checksum=_csum(np.asarray(cold_cc.labels)))

            # PageRank — warm restart iteration win (dense rounds)
            cold_pr = pagerank(e1_pr, max_iters=PR_ITERS)
            warm_pr = pagerank_warm(e1_pr, old_rank, max_iters=PR_ITERS)
            np.testing.assert_allclose(
                np.asarray(warm_pr.rank), np.asarray(cold_pr.rank),
                rtol=1e-4, atol=1e-7)
            if fam in ("road", "uniform"):
                assert int(warm_pr.iterations) < int(cold_pr.iterations), (
                    f"{fam}/{kind} warm pagerank took "
                    f"{int(warm_pr.iterations)} >= {int(cold_pr.iterations)}")
            emit("dynamic_updates", f"{fam}/{kind}/pagerank",
                 iters_cold=int(cold_pr.iterations),
                 iters_warm=int(warm_pr.iterations))

    _server_retention(quick)


def _server_retention(quick: bool):
    """Prove selective invalidation through the serving stack: a delta
    confined to the giant component must invalidate its entries while
    cached answers for other components migrate across the version bump
    and keep hitting (road dropout guarantees several components)."""
    g = datasets.road_graph(900 if quick else 2500, 2.4, seed=2)
    from repro.graphs.analytics import cc_reference
    labels = cc_reference(g.rows, g.cols, g.n)
    uniq, counts = np.unique(labels, return_counts=True)
    big = int(uniq[np.argmax(counts)])
    others = [int(np.nonzero(labels == u)[0][0])
              for u, c in zip(uniq, counts) if u != big and c >= 2][:3]
    assert others, "road dropout should leave small components"
    big_nodes = np.nonzero(labels == big)[0]

    srv = GraphQueryServer(g, batch_size=4, cache_capacity=256)
    for s in others:
        srv.submit("bfs", s)
        srv.submit("sssp", s)
    srv.submit("bfs", int(big_nodes[0]))
    srv.flush()
    ins = np.stack([big_nodes[3:11], big_nodes[20:28]], 1)
    report = srv.mutate(EdgeDelta(insert_rows=ins[:, 0],
                                  insert_cols=ins[:, 1]))
    stats = srv.stats()
    assert report["retained"] >= 2 * len(others), report
    assert report["invalidated"] >= 1, report
    assert stats["entries_retained"] == report["retained"]
    hits_before = stats["cache"]["hits"]
    for s in others:
        srv.submit("bfs", s)
    srv.flush()
    assert srv.stats()["cache"]["hits"] == hits_before + len(others), (
        "migrated entries must keep serving after mutate")
    emit("dynamic_updates", "road/server_mutate",
         retained=report["retained"], invalidated=report["invalidated"],
         version=srv.version)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)

"""Whole-graph analytics: engine vs sequential CPU reference (Table-4-style
accounting for the matrix-matrix / whole-vertex workload class).

For each Table-2 family (road / uniform / rmat) this times the four
analytics apps — connected components, full PageRank, masked-SpGEMM
triangle counting, k-core decomposition — on the jitted semiring engine
against their sequential numpy references, and reports speedup plus
compute utilization (useful semiring op rate / measured dense-matmul peak,
the paper's Table-4 metric on this container).

Useful-op accounting:
  iterative apps (cc / pagerank / kcore): 2·nnz per SpMV round × rounds
  triangle count: 2·Σ_k nnz(L[:,k])² — the products a masked L·Lᵀ
  actually combines (column-outer-product accounting), not the dense
  upper bound.

    PYTHONPATH=src:. python -m benchmarks.analytics [--quick]
"""
from benchmarks import common  # noqa: F401  (pins device count first)

import jax
import numpy as np

from benchmarks.common import bench_vs_reference, emit, peak_flops_cpu
from repro.core.semiring import MIN_TIMES, PLUS_AND, PLUS_TIMES
from repro.core.spgemm import spgemm_masked
from repro.graphs.analytics import (
    cc_reference, connected_components, kcore, kcore_reference, lower_triangle,
    pagerank_reference, triangle_problem, triangle_reference,
)
from repro.graphs.cost_model import trained_stump
from repro.graphs.datasets import generate
from repro.graphs.engine import build_engine
from repro.graphs.ppr import pagerank


def _bench(name, ds, engine_fn, ref_fn, ops_fn, peak):
    bench_vs_reference("analytics", f"{ds}/{name}", engine_fn, ref_fn,
                       ops_fn, peak)


def run(quick: bool = False):
    stump = trained_stump()
    peak = peak_flops_cpu(512 if quick else 1024)
    emit("analytics", "peak", gflops=peak / 1e9)
    # One dataset per Table-2 generator family; scales keep n small enough
    # for the dense Lᵀ operand of the triangle-count SpGEMM.
    datasets = ([("r-TX", 0.002), ("p2p-24", 0.08), ("face", 0.25),
                 ("as00", 0.3)]
                if not quick else [("face", 0.1), ("r-TX", 0.001)])
    for ds, scale in datasets:
        g = generate(ds, scale=scale, seed=0)
        emit("analytics", f"{ds}/graph", n=g.n, nnz=g.nnz)

        def whole_graph_ops(res):
            return 2.0 * g.nnz * int(res.iterations)

        # Connected components (⟨min,×⟩ label flooding)
        eng = build_engine(g, MIN_TIMES, stump)
        _bench("cc", ds, jax.jit(lambda: connected_components(eng)),
               lambda: cc_reference(g.rows, g.cols, g.n),
               whole_graph_ops, peak)

        # Full PageRank (⟨+,×⟩ power iteration, dense from step 0)
        eng = build_engine(g, PLUS_TIMES, stump, normalize=True)
        _bench("pagerank", ds, jax.jit(lambda: pagerank(eng)),
               lambda: pagerank_reference(g.rows, g.cols, g.n),
               whole_graph_ops, peak)

        # Triangle counting (masked SpGEMM over ⟨+,∧⟩); the container build
        # is host-side and untimed, like the paper's matrix-load phase.
        _, lc = lower_triangle(g)
        col_counts = np.bincount(lc, minlength=g.n).astype(np.float64)
        tri_ops = 2.0 * float(np.sum(col_counts ** 2))
        a, b, mask, _ = triangle_problem(g, impl="csr")
        _bench("triangles", ds,
               jax.jit(lambda: spgemm_masked(a, b, PLUS_AND, mask).sum()),
               lambda: triangle_reference(g.rows, g.cols, g.n),
               lambda _res: tri_ops, peak)

        # k-core decomposition (masked-SpMV degree peel)
        eng = build_engine(g, PLUS_TIMES, stump)
        _bench("kcore", ds, jax.jit(lambda: kcore(eng)),
               lambda: kcore_reference(g.rows, g.cols, g.n),
               whole_graph_ops, peak)


if __name__ == "__main__":
    run()

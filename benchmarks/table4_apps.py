"""Table 4: application execution time + compute utilization vs a classical
CPU baseline.

The paper compares UPMEM against GridGraph (CPU) / cuGraph (GPU). Without
that hardware, the roles map as: classical sequential numpy references =
the CPU baseline; the jitted ALPHA-PIM adaptive engine = the accelerated
system. Compute utilization = achieved useful semiring-op rate / measured
dense-matmul peak of this container — the paper's metric, same machine.
"""
from benchmarks import common  # noqa: F401

import time

import jax
import numpy as np

from benchmarks.common import emit, peak_flops_cpu, timeit
from repro.core.semiring import BOOL_OR_AND, MIN_PLUS, PLUS_TIMES
from repro.graphs import (
    bfs, bfs_reference, ppr, ppr_reference, sssp, sssp_reference,
)
from repro.graphs.cost_model import trained_stump
from repro.graphs.datasets import generate, largest_component_source
from repro.graphs.engine import build_engine, edge_values


def useful_ops(g, res) -> float:
    """2*nnz per effective full matvec, density-weighted per iteration."""
    dens = np.asarray(res.densities)
    dens = dens[dens >= 0]
    kern = np.asarray(res.kernel_used)[: len(dens)]
    ops = 0.0
    for d, k in zip(dens, kern):
        ops += 2.0 * g.nnz * (d if k == 0 else 1.0)
    return max(ops, 2.0 * g.nnz)


def run(quick: bool = False):
    stump = trained_stump()
    peak = peak_flops_cpu(512 if quick else 1024)
    emit("table4", "peak", gflops=peak / 1e9)
    datasets = (["A302", "as00", "s-S11", "p2p-24", "e-En", "face"]
                if not quick else ["face", "as00"])
    for ds in datasets:
        g = generate(ds, scale=0.05 if ds in ("A302", "s-S11") else 0.25,
                     seed=0)
        src = largest_component_source(g)

        # BFS
        eng = build_engine(g, BOOL_OR_AND, stump)
        f = jax.jit(lambda: bfs(eng, src, policy="adaptive"))
        t_pim = timeit(f, iters=3, warmup=1)
        t0 = time.perf_counter()
        bfs_reference(g.rows, g.cols, g.n, src)
        t_cpu = time.perf_counter() - t0
        res = f()
        util = useful_ops(g, res) / t_pim / peak
        emit("table4", f"{ds}/bfs", cpu_ms=t_cpu * 1e3, alpha_pim_ms=t_pim * 1e3,
             speedup=t_cpu / t_pim, util_pct=util * 100)

        # SSSP
        eng = build_engine(g, MIN_PLUS, stump, weighted=True, seed=5)
        w = edge_values(g, MIN_PLUS, weighted=True, seed=5)
        f = jax.jit(lambda: sssp(eng, src, policy="adaptive"))
        t_pim = timeit(f, iters=3, warmup=1)
        t0 = time.perf_counter()
        sssp_reference(g.rows, g.cols, w, g.n, src)
        t_cpu = time.perf_counter() - t0
        res = f()
        util = useful_ops(g, res) / t_pim / peak
        emit("table4", f"{ds}/sssp", cpu_ms=t_cpu * 1e3, alpha_pim_ms=t_pim * 1e3,
             speedup=t_cpu / t_pim, util_pct=util * 100)

        # PPR
        eng = build_engine(g, PLUS_TIMES, stump, normalize=True)
        f = jax.jit(lambda: ppr(eng, src, policy="adaptive"))
        t_pim = timeit(f, iters=3, warmup=1)
        t0 = time.perf_counter()
        ppr_reference(g.rows, g.cols, g.n, src)
        t_cpu = time.perf_counter() - t0
        res = f()
        util = useful_ops(g, res) / t_pim / peak
        emit("table4", f"{ds}/ppr", cpu_ms=t_cpu * 1e3, alpha_pim_ms=t_pim * 1e3,
             speedup=t_cpu / t_pim, util_pct=util * 100)


if __name__ == "__main__":
    run()

"""Table 4: application execution time + compute utilization vs a classical
CPU baseline.

The paper compares UPMEM against GridGraph (CPU) / cuGraph (GPU). Without
that hardware, the roles map as: classical sequential numpy references =
the CPU baseline; the jitted ALPHA-PIM adaptive engine = the accelerated
system. Compute utilization = achieved useful semiring-op rate / measured
dense-matmul peak of this container — the paper's metric, same machine.
"""
from benchmarks import common  # noqa: F401

import jax
import numpy as np

from benchmarks.common import bench_vs_reference, emit, peak_flops_cpu
from repro.core.semiring import BOOL_OR_AND, MIN_PLUS, MIN_TIMES, PLUS_TIMES
from repro.graphs import (
    bfs, bfs_reference, cc_reference, connected_components, pagerank,
    pagerank_reference, ppr, ppr_reference, sssp, sssp_reference,
)
from repro.graphs.cost_model import trained_stump
from repro.graphs.datasets import generate, largest_component_source
from repro.graphs.engine import build_engine, edge_values


def useful_ops(g, res) -> float:
    """2*nnz per effective full matvec, density-weighted per iteration."""
    dens = np.asarray(res.densities)
    dens = dens[dens >= 0]
    kern = np.asarray(res.kernel_used)[: len(dens)]
    ops = 0.0
    for d, k in zip(dens, kern):
        ops += 2.0 * g.nnz * (d if k == 0 else 1.0)
    return max(ops, 2.0 * g.nnz)


def _bench(case: str, engine_fn, ref_fn, ops_fn, peak: float) -> None:
    bench_vs_reference("table4", case, engine_fn, ref_fn, ops_fn, peak)


def run(quick: bool = False):
    stump = trained_stump()
    peak = peak_flops_cpu(512 if quick else 1024)
    emit("table4", "peak", gflops=peak / 1e9)
    datasets = (["A302", "as00", "s-S11", "p2p-24", "e-En", "face"]
                if not quick else ["face", "as00"])
    for ds in datasets:
        g = generate(ds, scale=0.05 if ds in ("A302", "s-S11") else 0.25,
                     seed=0)
        src = largest_component_source(g)

        def whole_graph_ops(res):
            return 2.0 * g.nnz * int(res.iterations)

        # BFS
        eng = build_engine(g, BOOL_OR_AND, stump)
        _bench(f"{ds}/bfs", jax.jit(lambda: bfs(eng, src, policy="adaptive")),
               lambda: bfs_reference(g.rows, g.cols, g.n, src),
               lambda res: useful_ops(g, res), peak)

        # SSSP
        eng = build_engine(g, MIN_PLUS, stump, weighted=True, seed=5)
        w = edge_values(g, MIN_PLUS, weighted=True, seed=5)
        _bench(f"{ds}/sssp", jax.jit(lambda: sssp(eng, src, policy="adaptive")),
               lambda: sssp_reference(g.rows, g.cols, w, g.n, src),
               lambda res: useful_ops(g, res), peak)

        # PPR
        eng = build_engine(g, PLUS_TIMES, stump, normalize=True)
        _bench(f"{ds}/ppr", jax.jit(lambda: ppr(eng, src, policy="adaptive")),
               lambda: ppr_reference(g.rows, g.cols, g.n, src),
               lambda res: useful_ops(g, res), peak)

        # Full PageRank (whole-graph: dense from step 0, SpMV every round)
        _bench(f"{ds}/pagerank", jax.jit(lambda: pagerank(eng)),
               lambda: pagerank_reference(g.rows, g.cols, g.n),
               whole_graph_ops, peak)

        # Connected components (whole-graph ⟨min,×⟩ label flooding)
        eng = build_engine(g, MIN_TIMES, stump)
        _bench(f"{ds}/cc", jax.jit(lambda: connected_components(eng)),
               lambda: cc_reference(g.rows, g.cols, g.n),
               whole_graph_ops, peak)


if __name__ == "__main__":
    run()

"""Shared benchmark machinery. IMPORTANT: import this module FIRST in every
benchmark (it pins the CPU device count before jax initializes)."""
from __future__ import annotations

import os
import sys
import time

N_DEVICES = int(os.environ.get("BENCH_DEVICES", "8"))
if "jax" not in sys.modules:
    os.environ.setdefault(
        "XLA_FLAGS", f"--xla_force_host_platform_device_count={N_DEVICES}")

import jax  # noqa: E402
import numpy as np  # noqa: E402


def timeit(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def bench_vs_reference(bench: str, case: str, engine_fn, ref_fn, ops_fn,
                       peak: float) -> None:
    """Median-time a jitted engine fn against one sequential-reference run
    and emit a Table-4-style row (cpu_ms / alpha_pim_ms / speedup /
    util_pct). ``ops_fn(result)`` -> useful semiring ops for utilization.
    The warmup run's result is reused for ops_fn, so the engine executes
    exactly warmup+iters times."""
    result = jax.block_until_ready(engine_fn())   # warmup, result kept
    t_pim = timeit(engine_fn, iters=3, warmup=0)
    t0 = time.perf_counter()
    ref_fn()
    t_cpu = time.perf_counter() - t0
    util = ops_fn(result) / t_pim / peak
    emit(bench, case, cpu_ms=t_cpu * 1e3, alpha_pim_ms=t_pim * 1e3,
         speedup=t_cpu / t_pim, util_pct=util * 100)


_rows = []


def emit(bench: str, case: str, **metrics):
    parts = [f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}"
             for k, v in metrics.items()]
    line = f"{bench},{case}," + ",".join(parts)
    print(line, flush=True)
    _rows.append({"bench": bench, "case": case, **metrics})


def rows():
    return list(_rows)


def peak_flops_cpu(n: int = 1024) -> float:
    """Measured f32 matmul peak of this container (for table4 utilization)."""
    import jax.numpy as jnp
    a = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    t = timeit(f, a, iters=3, warmup=2)
    return 2 * n ** 3 / t


def make_dense_vector(n: int, density: float, sr, seed: int = 0):
    """Vector with the given nonzero density in the semiring's domain."""
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    nz = rng.random(n) < density
    if sr.name == "min_plus":
        x = np.where(nz, rng.random(n).astype(np.float32), np.inf)
    elif sr.name == "bool_or_and":
        x = nz.astype(np.int32)
    else:
        x = np.where(nz, rng.random(n).astype(np.float32), 0.0).astype(np.float32)
    return jnp.asarray(x, sr.dtype)

"""Fig 2: execution-time breakdown of 1D vs 2D SpMV partitioning.

Paper: SparseP's COO.nnz (1D row) vs DCOO (2D), 2048 DPUs, int32 — 1D pays
for broadcasting the dense input vector; 2D pays retrieve+merge instead.
Here: COO row-wise vs COO 2D over the 8-device CPU mesh, dense input vector.
"""
from benchmarks import common  # noqa: F401  (must be first: device count)

import jax
import numpy as np

from benchmarks.common import emit, make_dense_vector, timeit
from benchmarks.phases import phase_times, prep, shard_x
from repro.core.semiring import PLUS_TIMES
from repro.graphs.datasets import generate


def run(quick: bool = False):
    mesh = jax.make_mesh((2, 4), ("dr", "dc"))
    scale = 0.05 if quick else 0.15
    sr = PLUS_TIMES
    for ds in ["face", "A302"] if not quick else ["face"]:
        g = generate(ds, scale=scale, seed=0)
        x = np.asarray(make_dense_vector(g.n, 1.0, sr))
        base = None
        for case, grid, strategy in [("1D-row", (8, 1), "row"),
                                     ("2D", (2, 4), "2d")]:
            pm = prep(g, sr, grid, "coo")
            xs = shard_x(x, pm, sr)
            t = phase_times(mesh, pm, sr, strategy, "spmv", xs, timeit)
            if base is None:
                base = t["e2e"]
            emit("fig2", f"{ds}/{case}",
                 load_ms=t["load"] * 1e3, kernel_ms=t["kernel"] * 1e3,
                 retrieve_merge_ms=t["retrieve_merge"] * 1e3,
                 e2e_ms=t["e2e"] * 1e3, norm_to_1d=t["e2e"] / base)


if __name__ == "__main__":
    run()

"""Fig 6: best SpMV vs best SpMSpV (CSC-2D) across input-vector densities
1/10/30/50% — SpMSpV's load-cost advantage shrinks as the vector densifies.
"""
from benchmarks import common  # noqa: F401

import jax
import numpy as np

from benchmarks.common import emit, make_dense_vector, timeit
from benchmarks.phases import phase_times, prep, shard_x
from repro.core.semiring import PLUS_TIMES
from repro.graphs.datasets import generate


def run(quick: bool = False):
    mesh = jax.make_mesh((2, 4), ("dr", "dc"))
    sr = PLUS_TIMES
    datasets = ["face", "A302"] if not quick else ["face"]
    for ds in datasets:
        g = generate(ds, scale=0.05 if ds == "A302" else 0.2, seed=0)
        pm_mv = prep(g, sr, (2, 4), "coo")      # paper's DCOO analogue
        pm_msv = prep(g, sr, (2, 4), "csc")     # CSC-2D
        for dens in [0.01, 0.10, 0.30, 0.50]:
            x = np.asarray(make_dense_vector(g.n, dens, sr, seed=7))
            t_mv = phase_times(mesh, pm_mv, sr, "2d", "spmv",
                               shard_x(x, pm_mv, sr), timeit)
            n_per = pm_msv.shape[1] // pm_msv.n_devices
            f_local = max(32, int(dens * n_per * 4) // 8 * 8)
            t_msv = phase_times(mesh, pm_msv, sr, "2d", "spmspv",
                                shard_x(x, pm_msv, sr), timeit,
                                f_local=f_local)
            emit("fig6", f"{ds}/d{int(dens*100)}",
                 spmv_ms=t_mv["e2e"] * 1e3, spmspv_ms=t_msv["e2e"] * 1e3,
                 spmspv_vs_spmv=t_msv["e2e"] / t_mv["e2e"],
                 spmv_load_ms=t_mv["load"] * 1e3,
                 spmspv_load_ms=t_msv["load"] * 1e3)


if __name__ == "__main__":
    run()

"""Fig 4: per-iteration execution time + frontier density for BFS and SSSP
under SpMV-only vs SpMSpV-only policies — the crossover that motivates
adaptive switching (§4.2).
"""
from benchmarks import common  # noqa: F401

import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core.semiring import BOOL_OR_AND, MIN_PLUS
from repro.graphs.cost_model import trained_stump
from repro.graphs.datasets import generate, largest_component_source
from repro.graphs.engine import build_engine, density_of


def _trace(engine, x0, visited0, sr, max_iters, update):
    """Python-level iteration loop so each level is timed separately."""
    import jax
    spmv = jax.jit(engine.spmv_fn)
    spmspv = jax.jit(engine.spmspv_fn)
    x, visited = x0, visited0
    rows = []
    for it in range(max_iters):
        dens = float(density_of(x, sr, engine.n_true))
        if dens == 0.0:
            break
        t_mv = timeit(spmv, x, iters=3, warmup=1)
        t_msv = timeit(spmspv, x, iters=3, warmup=1)
        y = spmv(x)
        x, visited, done = update(y, x, visited)
        rows.append((it, dens, t_mv, t_msv))
        if done:
            break
    return rows


def run(quick: bool = False):
    stump = trained_stump()
    datasets = ["A302", "r-TX"] if not quick else ["A302"]
    for ds in datasets:
        g = generate(ds, scale=0.05, seed=0)
        src = largest_component_source(g)

        # BFS trace
        eng = build_engine(g, BOOL_OR_AND, stump)
        sr = BOOL_OR_AND
        x0 = jnp.zeros((eng.n,), sr.dtype).at[src].set(1)
        vis0 = jnp.zeros((eng.n,), jnp.int32).at[src].set(1)

        def bfs_update(y, x, visited):
            nf = jnp.where((y != 0) & (visited == 0), 1, 0).astype(sr.dtype)
            visited = jnp.where(nf != 0, 1, visited)
            return nf, visited, bool(jnp.sum(nf) == 0)

        for it, dens, t_mv, t_msv in _trace(eng, x0, vis0, sr, 32, bfs_update):
            emit("fig4", f"{ds}/bfs/it{it}", density=dens,
                 spmv_ms=t_mv * 1e3, spmspv_ms=t_msv * 1e3,
                 threshold=eng.threshold)

        # SSSP trace (min-plus relaxation rounds)
        eng = build_engine(g, MIN_PLUS, stump, weighted=True)
        sr = MIN_PLUS
        d0 = jnp.full((eng.n,), jnp.inf, sr.dtype).at[src].set(0.0)

        def sssp_update(y, x, dist):
            new_d = jnp.minimum(dist, y)
            frontier = jnp.where(new_d < dist, new_d, jnp.inf)
            return frontier, new_d, bool(jnp.all(new_d >= dist))

        for it, dens, t_mv, t_msv in _trace(
                eng, d0, d0, sr, 16 if quick else 24, sssp_update):
            emit("fig4", f"{ds}/sssp/it{it}", density=dens,
                 spmv_ms=t_mv * 1e3, spmspv_ms=t_msv * 1e3,
                 threshold=eng.threshold)


if __name__ == "__main__":
    run()

"""Overlap efficiency of the pipelined phase engine (the paper's
non-blocking-DMA recommendation, core.pipeline).

For each Fig.-3 partitioning strategy and Table-2 family, an n-iteration
PageRank-style traversal loop (column-stochastic ⟨+,×⟩ SpMV) is run three
ways over the *same* per-phase closures
(core.distributed.build_phase_fns):

* ``phase_sum``  — the sequential per-phase accounting of
  benchmarks/phases.py: each phase timed in isolation with a blocking
  sync, summed over phases and iterations. This is the schedule UPMEM's
  blocking DMA enforces — the paper's measured baseline.
* ``blocking``   — wall time of the loop with a hard sync after every
  phase (core.pipeline.iterate_phases, depth=0).
* ``pipelined``  — wall time with phases dispatched asynchronously and up
  to ``depth`` iterations in flight (depth>=1), so Retrieve+Merge of
  iteration t overlaps the Load of t+1.

``overlap_eff = 1 - pipelined/phase_sum`` is the fraction of the
sequential phase-sum hidden by the non-blocking schedule. Results are
bit-identical across schedules (asserted in tests/test_distributed.py);
this module only reports time.
"""
from benchmarks import common  # noqa: F401  (pins device count first)

import time

import jax
import numpy as np

from benchmarks.common import emit, make_dense_vector, timeit
from benchmarks.phases import phase_times, prep, shard_x
from repro.core.distributed import build_phase_fns
from repro.core.pipeline import iterate_phases
from repro.core.semiring import PLUS_TIMES
from repro.graphs.datasets import generate

# one family per Table-2 generator class: rmat / uniform / road
FAMILIES = ["face", "p2p-24", "r-TX"]
STRATEGIES = [("row", (8, 1), "csr"), ("col", (1, 8), "coo"),
              ("2d", (2, 4), "coo")]


def _wall(fn, iters: int = 5) -> float:
    """Min wall seconds of ``fn()`` over ``iters`` reps (fn blocks
    internally; min de-noises scheduler jitter on a shared host)."""
    fn()  # warmup (compilation of every phase closure)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.min(ts))


def run(quick: bool = False, depth: int = 4):
    sr = PLUS_TIMES
    mesh = jax.make_mesh((2, 4), ("dr", "dc"))
    families = FAMILIES[:2] if quick else FAMILIES
    # Iteration count amortizes the per-phase sync cost the pipeline
    # removes; graph scales keep the loop latency-bound (the paper's
    # small-transfer regime, where blocking DMA hurts most).
    n_iters = 16 if quick else 32
    scale = {"face": 0.2, "p2p-24": 0.1, "r-TX": 0.004}
    wins = []
    for fam in families:
        g = generate(fam, scale=scale[fam] * (0.5 if quick else 1.0), seed=0)
        x = np.asarray(make_dense_vector(g.n, 1.0, sr, seed=1))
        for strategy, grid, fmt in STRATEGIES:
            pm = prep(g, sr, grid, fmt, normalize=True)
            xs = shard_x(x, pm, sr)
            # one closure set per cell: phase_times re-times the same
            # compiled fns the pipelined/blocking loops execute
            fns = build_phase_fns(mesh, pm, sr, strategy, "spmv")
            t = phase_times(mesh, pm, sr, strategy, "spmv", xs, timeit,
                            fns=fns)
            phase_sum = (t["load"] + t["kernel"] + t["retrieve_merge"]) \
                * n_iters
            t_blk = _wall(lambda: iterate_phases(fns, pm.parts, xs, n_iters,
                                                 depth=0))
            t_pip = _wall(lambda: iterate_phases(fns, pm.parts, xs, n_iters,
                                                 depth=depth))
            overlapped = t_pip < phase_sum
            wins.append((fam, strategy, overlapped))
            emit("pipeline_overlap", f"{fam}/{strategy}",
                 phase_sum_ms=phase_sum * 1e3, blocking_ms=t_blk * 1e3,
                 pipelined_ms=t_pip * 1e3,
                 overlap_eff=1.0 - t_pip / phase_sum,
                 speedup_vs_blocking=t_blk / t_pip,
                 pipelined_below_phase_sum=int(overlapped))
    hidden = sum(1 for *_k, ok in wins if ok)
    print(f"pipeline_overlap: pipelined wall below sequential phase-sum in "
          f"{hidden}/{len(wins)} (family, strategy) cells", flush=True)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--depth", type=int, default=4,
                    help="max in-flight iterations of the pipelined run")
    args = ap.parse_args()
    run(quick=args.quick, depth=args.depth)

"""§4.2.1 sensitivity: a +-10% error in the switch threshold must cost
<5% total runtime on average (paper; A302 example: 60% vs 50% -> +2.5%).
"""
from benchmarks import common  # noqa: F401

import dataclasses

import jax

from benchmarks.common import emit, timeit
from repro.core.semiring import BOOL_OR_AND
from repro.graphs import bfs
from repro.graphs.cost_model import trained_stump
from repro.graphs.datasets import generate, largest_component_source
from repro.graphs.engine import build_engine


def run(quick: bool = False):
    stump = trained_stump()
    datasets = ["A302", "face"] if not quick else ["face"]
    deltas = [-0.2, -0.1, 0.0, 0.1, 0.2]
    for ds in datasets:
        g = generate(ds, scale=0.05 if ds == "A302" else 0.3, seed=0)
        src = largest_component_source(g)
        eng0 = build_engine(g, BOOL_OR_AND, stump)
        base = None
        for dlt in deltas:
            eng = dataclasses.replace(eng0, threshold=eng0.threshold + dlt)
            f = jax.jit(lambda e=eng: bfs(e, src, policy="adaptive"))
            t = timeit(f, iters=3, warmup=1)
            if dlt == 0.0:
                base = t
        for dlt in deltas:
            eng = dataclasses.replace(eng0, threshold=eng0.threshold + dlt)
            f = jax.jit(lambda e=eng: bfs(e, src, policy="adaptive"))
            t = timeit(f, iters=3, warmup=1)
            emit("sensitivity", f"{ds}/thr{eng.threshold:+.2f}",
                 total_ms=t * 1e3, delta_pct=(t / base - 1) * 100)


if __name__ == "__main__":
    run()
